"""The normalised reward function (paper §3.4, Eq. 1).

    Reward = -w1 * nBDE + w2 * nIP + w3 * γ

* nBDE/nIP are min-max normalised with bounds taken from the *training
  dataset* properties ("The lower bound and upper bound are minimal and
  maximum properties in the proprietary data set").
* weights default to the paper's (0.8, 0.2, 0.5) — Table 3.
* γ rewards shrinking the molecule: "the relatively reduced atoms and bonds
  from the initial molecule".
* per-property factors (Table 3: BDE Factor 0.9, IP Factor 0.8) are applied
  as step-decays ``factor ** steps_left`` — early in the episode the agent
  sees weaker property signal, at the terminal step the full value (this is
  the MolDQN per-step discounting convention applied per property).
* molecules without a valid 3D conformer get INVALID_CONFORMER_REWARD
  (-1000, §3.3) — "much less than the normal rewards".
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.chem.molecule import Molecule

INVALID_CONFORMER_REWARD = -1000.0


@dataclass(frozen=True)
class RewardConfig:
    bde_weight: float = 0.8     # w1
    ip_weight: float = 0.2      # w2
    gamma_weight: float = 0.5   # w3
    bde_factor: float = 0.9
    ip_factor: float = 0.8
    # min-max normalisation bounds (from the training set; §3.4)
    bde_min: float = 55.0
    bde_max: float = 95.0
    ip_min: float = 95.0
    ip_max: float = 200.0

    @classmethod
    def from_dataset(cls, bde_values, ip_values, **kw) -> "RewardConfig":
        import numpy as np
        return cls(
            bde_min=float(np.min(bde_values)), bde_max=float(np.max(bde_values)),
            ip_min=float(np.min(ip_values)), ip_max=float(np.max(ip_values)),
            **kw,
        )

    # ------------------------------------------------------------ #
    def normalize_bde(self, bde: float) -> float:
        return (bde - self.bde_min) / max(self.bde_max - self.bde_min, 1e-9)

    def normalize_ip(self, ip: float) -> float:
        return (ip - self.ip_min) / max(self.ip_max - self.ip_min, 1e-9)


def gamma_term(initial: Molecule, current: Molecule) -> float:
    """Relative reduction of atoms + bonds vs the initial molecule."""
    a0 = max(initial.num_atoms, 1)
    b0 = max(initial.num_bonds, 1)
    da = (a0 - current.num_atoms) / a0
    db = (b0 - current.num_bonds) / b0
    return 0.5 * (da + db)


def compute_reward(
    cfg: RewardConfig,
    *,
    bde: float | None,
    ip: float | None,
    initial: Molecule,
    current: Molecule,
    steps_left: int = 0,
) -> float:
    """Eq. 1.  ``ip is None`` means no valid 3D conformer -> -1000 (§3.3).
    ``bde is None`` (no O-H bond) is unreachable through protected actions
    but treated identically for robustness."""
    if ip is None or bde is None:
        return INVALID_CONFORMER_REWARD
    nbde = cfg.normalize_bde(bde) * (cfg.bde_factor ** steps_left)
    nip = cfg.normalize_ip(ip) * (cfg.ip_factor ** steps_left)
    return -cfg.bde_weight * nbde + cfg.ip_weight * nip + cfg.gamma_weight * gamma_term(initial, current)
