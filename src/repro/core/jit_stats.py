"""Recompile accounting for the acting hot path.

The fleet rollout's perf claims rest on *shape discipline*: after warmup,
no environment step may trigger an XLA compile.  Two observers:

``RecompileCounter``  process-global compile counter built on
                      ``jax.monitoring``.  JAX emits
                      '/jax/compilation_cache/compile_requests_use_cache'
                      once per backend compile request (including nested
                      sub-jits) and nothing on tracing-cache hits, so a
                      window with delta == 0 provably ran entirely on
                      already-compiled shapes.  The count is monotone and
                      includes every jit in the process (predictors too),
                      which is exactly what the CI smoke gate wants.

``jit_cache_size``    per-function tracing-cache size (``fn._cache_size()``)
                      for pinpointing WHICH function grew when the global
                      counter fires.
"""

from __future__ import annotations

import jax.monitoring

_COMPILE_EVENT_PREFIXES = (
    "/jax/compilation_cache/compile_requests",
)


class RecompileCounter:
    """Singleton listener over jax.monitoring compile events.

    Usage::

        counter = RecompileCounter.install()
        ...warmup...
        mark = counter.count
        ...measured work...
        recompiles = counter.count - mark   # 0 == no new XLA compiles
    """

    _instance: "RecompileCounter | None" = None

    def __init__(self) -> None:
        self.count = 0

    @classmethod
    def install(cls) -> "RecompileCounter":
        if cls._instance is None:
            inst = cls()
            # listeners cannot be unregistered on jax 0.4.x, hence singleton
            jax.monitoring.register_event_listener(inst._on_event)
            cls._instance = inst
        return cls._instance

    def _on_event(self, event: str, **kwargs) -> None:
        if event.startswith(_COMPILE_EVENT_PREFIXES):
            self.count += 1

    def delta_since(self, mark: int) -> int:
        return self.count - mark


def jit_cache_size(fn) -> int:
    """Tracing-cache entry count of a ``jax.jit``-wrapped function."""
    return fn._cache_size()
