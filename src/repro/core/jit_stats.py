"""Recompile accounting for the acting hot path.

The fleet rollout's perf claims rest on *shape discipline*: after warmup,
no environment step may trigger an XLA compile.  Two observers:

``RecompileCounter``  process-global compile counter built on
                      ``jax.monitoring``.  JAX emits
                      '/jax/compilation_cache/compile_requests_use_cache'
                      once per backend compile request (including nested
                      sub-jits) and nothing on tracing-cache hits, so a
                      window with delta == 0 provably ran entirely on
                      already-compiled shapes.  The count is monotone and
                      includes every jit in the process (predictors too),
                      which is exactly what the CI smoke gate wants.

``jit_cache_size``    per-function tracing-cache size (``fn._cache_size()``)
                      for pinpointing WHICH function grew when the global
                      counter fires.
"""

from __future__ import annotations

import jax.monitoring

_COMPILE_EVENT_PREFIXES = (
    "/jax/compilation_cache/compile_requests",
)


class RecompileCounter:
    """Singleton listener over jax.monitoring compile events.

    Usage::

        counter = RecompileCounter.install()
        ...warmup...
        mark = counter.count
        ...measured work...
        recompiles = counter.count - mark   # 0 == no new XLA compiles
    """

    _instance: "RecompileCounter | None" = None

    def __init__(self) -> None:
        self.count = 0

    @classmethod
    def install(cls) -> "RecompileCounter":
        if cls._instance is None:
            inst = cls()
            # listeners cannot be unregistered on jax 0.4.x, hence singleton
            jax.monitoring.register_event_listener(inst._on_event)
            cls._instance = inst
        return cls._instance

    def _on_event(self, event: str, **kwargs) -> None:
        if event.startswith(_COMPILE_EVENT_PREFIXES):
            self.count += 1

    def delta_since(self, mark: int) -> int:
        return self.count - mark

    def window(self) -> "CompileWindow":
        """Context manager over a measured region::

            with counter.window() as w:
                ...measured work...
            assert w.count == 0      # no XLA compiles inside the block

        ``w.count`` is live inside the block and frozen at exit — the idiom
        the multi-device verification runner and the benchmark smoke gates
        share for their recompiles-after-warmup gates.
        """
        return CompileWindow(self)


class CompileWindow:
    """Compile count within a ``with`` region (see ``RecompileCounter.window``)."""

    def __init__(self, counter: RecompileCounter) -> None:
        self._counter = counter
        self._mark = counter.count
        self._final: int | None = None

    @property
    def count(self) -> int:
        if self._final is not None:
            return self._final
        return self._counter.count - self._mark

    def __enter__(self) -> "CompileWindow":
        self._mark = self._counter.count
        self._final = None
        return self

    def __exit__(self, *exc) -> bool:
        self._final = self._counter.count - self._mark
        return False


def jit_cache_size(fn) -> int:
    """Tracing-cache entry count of a ``jax.jit``-wrapped function."""
    return fn._cache_size()
