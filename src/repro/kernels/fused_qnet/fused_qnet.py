"""Fused DQN Q-network evaluation (Pallas TPU).

The paper's per-step hot loop (§3.1): every environment step evaluates
Q over ~10^2 candidate-action fingerprints per molecule x the worker's
modification batch — thousands of rows through the MolDQN MLP
(2049 -> 1024 -> 512 -> 128 -> 32 -> 1).  The XLA path launches 5 matmul
kernels with HBM round-trips for each intermediate; this kernel keeps ALL
weights plus one row-block resident in VMEM and fuses the whole forward:

  VMEM budget (f32): W1 8.0 MiB + W2 2.0 MiB + W3/W4/W5 <0.3 MiB
                     + x block (128 x 2049) 1.0 MiB + h 0.5 MiB  ~= 12 MiB

Grid = (row blocks,): one pass over HBM for x, one output write — the
arithmetic-intensity fix for a memory-bound MLP (see EXPERIMENTS.md §Perf).
Row blocks of 128 keep the MXU M-dim aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _qnet_kernel(x_ref, w1, b1, w2, b2, w3, b3, w4, b4, w5, b5, out_ref):
    h = x_ref[...].astype(jnp.float32)
    h = jnp.maximum(jax.lax.dot_general(
        h, w1[...], (((1,), (0,)), ((), ()))) + b1[...], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w2[...], (((1,), (0,)), ((), ()))) + b2[...], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w3[...], (((1,), (0,)), ((), ()))) + b3[...], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w4[...], (((1,), (0,)), ((), ()))) + b4[...], 0.0)
    q = jax.lax.dot_general(h, w5[...], (((1,), (0,)), ((), ()))) + b5[...]
    out_ref[...] = q[:, 0].astype(out_ref.dtype)


def fused_qnet_rows(
    x: jnp.ndarray,            # [N, in_dim]
    weights: list[tuple[jnp.ndarray, jnp.ndarray]],   # [(w, b)] x5
    *,
    row_block: int = ROW_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    N, in_dim = x.shape
    assert len(weights) == 5, "fused kernel is specialised to the MolDQN 5-layer MLP"
    row_block = min(row_block, N)
    assert N % row_block == 0, f"rows {N} % block {row_block}"
    grid = (N // row_block,)

    full = lambda w: pl.BlockSpec(w.shape, lambda i: (0,) * w.ndim)
    in_specs = [pl.BlockSpec((row_block, in_dim), lambda i: (i, 0))]
    flat_w = []
    for w, b in weights:
        in_specs += [full(w), full(b)]
        flat_w += [w, b]

    return pl.pallas_call(
        _qnet_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), x.dtype),
        interpret=interpret,
    )(x, *flat_w)
