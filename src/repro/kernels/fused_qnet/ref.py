"""Pure-jnp oracle: the QNetwork forward from repro.core.agent."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qnet_ref(x: jnp.ndarray, weights: list[tuple[jnp.ndarray, jnp.ndarray]]) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    for li, (w, b) in enumerate(weights):
        h = h @ w + b
        if li < len(weights) - 1:
            h = jax.nn.relu(h)
    return h[..., 0].astype(x.dtype)
