from repro.kernels.fused_qnet.ops import fused_qnet

__all__ = ["fused_qnet"]
