"""jit'd wrapper: adapts QNetwork param pytrees + pads row counts."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_qnet.fused_qnet import ROW_BLOCK, fused_qnet_rows


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def fused_qnet(params: dict, x: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """params: QNetwork pytree ({"layers": [{"w","b"}, ...x5]}); x [N, 2049]."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    weights = [(l["w"], l["b"]) for l in params["layers"]]
    n = x.shape[0]
    padded = ((n + ROW_BLOCK - 1) // ROW_BLOCK) * ROW_BLOCK
    if padded != n:
        x = jnp.concatenate([x, jnp.zeros((padded - n, x.shape[1]), x.dtype)])
    q = fused_qnet_rows(x, weights, interpret=interpret)
    return q[:n]
