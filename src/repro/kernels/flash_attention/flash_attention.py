"""Flash attention (Pallas TPU): blocked online-softmax GQA attention.

TPU adaptation of the flash-attention idea (DESIGN.md §4): the score tensor
never leaves VMEM.  Grid (batch, q_head, q_blocks, kv_blocks); the last
grid dim is innermost and sequential on TPU, so the running (max, sum,
accumulator) state lives in VMEM scratch across kv-block iterations.
Causal / sliding-window / prefix-LM masks are generated from block indices
with iota — no [S, S] mask tensor exists anywhere.

Block shapes default to (128, 512): MXU-aligned (multiples of 128 on the
contracting/lane dims) and small enough that q, k, v blocks + f32
accumulator fit VMEM at head_dim <= 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 512
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               prefix_len: int, bq: int, bk: int, nk: int, seq_q: int, seq_k: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # [bq, bk]

    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < seq_k
    if causal:
        mask = mask & (kj <= qi)
    if window is not None:
        mask = mask & (kj > qi - window)
    if prefix_len > 0:
        mask = mask | ((kj < prefix_len) & (kj < seq_k))
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,   # [B, H, Sq, D]
    k: jnp.ndarray,   # [B, K, Sk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Sk, bk)
    scale = D ** -0.5
    rep = H // K

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        prefix_len=prefix_len, bq=bq, bk=bk, nk=nk, seq_q=Sq, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
