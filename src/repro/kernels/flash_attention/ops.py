"""jit'd public wrapper for the flash-attention kernel.

``flash_attention`` accepts the model-layer layout ([B, S, H, D] /
[B, S, K, D]) and handles the transposes; on non-TPU backends it runs the
kernel in interpret mode (Python emulation of the kernel body — the
correctness path this container validates)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "prefix_len", "interpret"))
def flash_attention(
    q: jnp.ndarray,   # [B, Sq, H, D]
    k: jnp.ndarray,   # [B, Sk, K, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = (not _on_tpu()) if interpret is None else interpret
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window, prefix_len=prefix_len, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
