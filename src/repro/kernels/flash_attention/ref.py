"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,   # [B, H, Sq, D]
    k: jnp.ndarray,   # [B, K, Sk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    rep = H // K
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * D ** -0.5, kf)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = mask & (kj > qi - window)
    if prefix_len > 0:
        mask = mask | (kj < prefix_len)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)
