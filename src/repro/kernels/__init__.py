"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>/{<name>.py, ops.py, ref.py}:
  flash_attention  blocked online-softmax GQA attention (causal / SWA /
                   prefix-LM) — the prefill/train attention hot spot
  ssd_scan         Mamba2 SSD chunked scan with VMEM state carry
  fused_qnet       the paper's DQN MLP fused end-to-end in VMEM (§3.6's
                   hot-loop optimisation, TPU-idiomatic form)

All are validated against their pure-jnp oracles in interpret mode on CPU
(tests/test_kernels.py) and are TARGETS for real TPUs — the dry-run
deliberately lowers the jnp paths so the roofline reads transparent HLO.
"""
