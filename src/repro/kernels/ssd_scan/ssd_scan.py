"""Mamba2 SSD chunked scan (Pallas TPU).

One (batch, head) pair per outer grid position; the innermost grid dim
walks the sequence chunks SEQUENTIALLY (TPU grid order), carrying the
[P, N] state in VMEM scratch — the kernel-level realisation of the
``ssd_chunked`` inter-chunk scan in ``repro.models.ssm``.

Per chunk (Q = chunk length) the quadratic "attention form" runs on the
MXU: scores = C B^T, gated by the decay triangle, plus the state
carry-in/carry-out terms.  All f32 accumulation; chunk length 128/256
keeps (Q x Q) + (Q x N) + (P x N) well inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr, *,
                nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [Q]
    A = a_ref[0].astype(jnp.float32)                   # scalar
    B = b_ref[0, :, 0, :].astype(jnp.float32)          # [Q, N]
    C = c_ref[0, :, 0, :].astype(jnp.float32)          # [Q, N]

    log_a = -A * dt                                    # [Q]
    cum = jnp.cumsum(log_a)                            # [Q]
    total = cum[-1]

    # intra-chunk attention form
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # [Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    gate = jnp.where(kj <= qi, decay, 0.0)
    xdt = x * dt[:, None]                              # [Q, P]
    y = jax.lax.dot_general(scores * gate, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk: incoming state
    state = state_scr[...]                             # [P, N]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())))            # [Q, P]

    # state update: S <- exp(total) S + sum_u exp(total - cum_u) dt_u x_u B_u^T
    w = jnp.exp(total - cum)[:, None] * xdt            # [Q, P]
    contrib = jax.lax.dot_general(w, B, (((0,), (0,)), ((), ())))  # [P, N]
    state_scr[...] = jnp.exp(total) * state + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0, 0] = state_scr[...].astype(st_ref.dtype)


def ssd_scan_blhp(
    x: jnp.ndarray,    # [B, L, H, P]
    dt: jnp.ndarray,   # [B, L, H]
    A: jnp.ndarray,    # [H]
    B_: jnp.ndarray,   # [B, L, G, N]
    C_: jnp.ndarray,   # [B, L, G, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    chunk = min(chunk, L)
    assert L % chunk == 0, f"L={L} % chunk={chunk}"
    nc = L // chunk
    rep = H // G

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, final = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_, C_)
    return y, final
