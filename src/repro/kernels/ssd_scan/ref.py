"""Pure-jnp oracle for the SSD-scan kernel: the naive O(L) recurrence.

Deliberately NOT the chunked algorithm (that's what both the kernel and
``repro.models.ssm.ssd_chunked`` implement) — testing chunked-vs-chunked
would hide shared algebra bugs.  This is the definitional recurrence:

    S_t = exp(-A dt_t) S_{t-1} + dt_t x_t B_t^T ;  y_t = C_t S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,    # [B, L, H, P]
    dt: jnp.ndarray,   # [B, L, H]
    A: jnp.ndarray,    # [H]
    B_: jnp.ndarray,   # [B, L, G, N]
    C_: jnp.ndarray,   # [B, L, G, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C_, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp                       # [B,H,P], [B,H], [B,H,N] x2
        a = jnp.exp(-Af[None, :] * dtt)             # [B,H]
        S = a[..., None, None] * S + jnp.einsum("bhp,bh,bhn->bhpn", xt, dtt, Bt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1)                       # [B, L, H, P]
    return y.astype(x.dtype), S.astype(x.dtype)
