"""jit'd public wrapper for the SSD-scan kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_blhp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,    # [B, L, H, P]
    dt: jnp.ndarray,   # [B, L, H]
    A: jnp.ndarray,    # [H]
    B_: jnp.ndarray,   # [B, L, G, N]
    C_: jnp.ndarray,   # [B, L, G, N]
    *,
    chunk: int = 128,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ssd_scan_blhp(x, dt, A, B_, C_, chunk=chunk, interpret=interpret)
