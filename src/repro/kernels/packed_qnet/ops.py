"""jit'd wrapper: adapts QNetwork param pytrees, packs W1 into bit-plane
slices, pads row counts, and picks the implementation:

* ``impl="pallas"`` — the fused bit-plane kernel (interpret mode off-TPU);
* ``impl="xla"``    — unpack-in-jit + dense forward (the portable default
                      everywhere but TPU);
* ``impl=None``     — pallas on TPU, xla otherwise.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.chem.fingerprint import FP_BITS
from repro.kernels.packed_qnet.packed_qnet import (
    ROW_BLOCK, packed_qnet_rows, packed_qnet_stacked_rows)
from repro.kernels.packed_qnet.ref import packed_qnet_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_w1(w1: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """W1 [FP_BITS+1, H1] -> (w1r [8, FP_BITS/8, H1], w1f [1, H1]).

    ``w1r[k, i] == w1[8*i + k]``: bit-plane k of byte i (np.unpackbits
    order, MSB first) multiplies exactly the weight rows its bits select."""
    wbits = w1[:FP_BITS].reshape(FP_BITS // 8, 8, -1).transpose(1, 0, 2)
    return wbits, w1[FP_BITS:]


@partial(jax.jit, static_argnames=("impl", "interpret"))
def packed_qnet(params: dict, bits: jnp.ndarray, frac: jnp.ndarray, *,
                impl: str | None = None, interpret: bool | None = None) -> jnp.ndarray:
    """params: QNetwork pytree ({"layers": [{"w","b"}, ...x5]});
    bits u8 [N, FP_BITS/8]; frac f32 [N] -> q f32 [N]."""
    weights = [(l["w"], l["b"]) for l in params["layers"]]
    if impl is None:
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return packed_qnet_ref(bits, frac, weights)
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = bits.shape[0]
    padded = ((n + ROW_BLOCK - 1) // ROW_BLOCK) * ROW_BLOCK
    if padded != n:
        bits = jnp.concatenate(
            [bits, jnp.zeros((padded - n, bits.shape[1]), bits.dtype)])
        frac = jnp.concatenate([frac, jnp.zeros((padded - n,), frac.dtype)])
    w1r, w1f = pack_w1(weights[0][0])
    q = packed_qnet_rows(bits, frac[:, None].astype(jnp.float32), w1r, w1f,
                         weights[0][1], weights[1:], interpret=interpret)
    return q[:n]


@partial(jax.jit, static_argnames=("impl", "interpret"))
def packed_qnet_stacked(params: dict, bits: jnp.ndarray, frac: jnp.ndarray, *,
                        impl: str | None = None,
                        interpret: bool | None = None) -> jnp.ndarray:
    """The fleet-acting shape: params is a STACKED QNetwork pytree (leaves
    ``[W, ...]``, one tree per worker); bits u8 [W, C, FP_BITS/8]; frac f32
    [W, C] -> q f32 [W, C] — the packed twin of ``QNetwork.apply_stacked``."""
    weights = [(l["w"], l["b"]) for l in params["layers"]]
    if impl is None:
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla":
        return jax.vmap(packed_qnet_ref, in_axes=(0, 0, 0))(bits, frac, weights)
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = bits.shape[1]
    padded = max(((n + ROW_BLOCK - 1) // ROW_BLOCK) * ROW_BLOCK, ROW_BLOCK)
    if padded != n:
        pad = ((0, 0), (0, padded - n), (0, 0))
        bits = jnp.pad(bits, pad)
        frac = jnp.pad(frac, pad[:2])
    # vmap'd pack_w1: per-worker bit-plane slices [W, 8, FP_BITS/8, H1]
    w1r, w1f = jax.vmap(pack_w1)(weights[0][0])
    q = packed_qnet_stacked_rows(bits, frac[..., None].astype(jnp.float32),
                                 w1r, w1f, weights[0][1], weights[1:],
                                 interpret=interpret)
    return q[:, :n]
