"""Pure-jnp oracle: unpack-in-jit + the dense QNetwork forward.

This is also the DEFAULT PORTABLE PATH for packed Q evaluation (what
``ops.packed_qnet`` runs off-TPU): XLA unpacks the bit planes in-jit and
fuses the {0,1} float matmul — no Pallas required, identical math to
``QNetwork.apply`` on the densified input.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packed_batch import unpack_bits
from repro.kernels.fused_qnet.ref import qnet_ref


def packed_qnet_ref(bits: jnp.ndarray, frac: jnp.ndarray,
                    weights: list[tuple[jnp.ndarray, jnp.ndarray]]) -> jnp.ndarray:
    """bits u8 [..., FP_BITS/8], frac f32 [...] -> q f32 [...]."""
    x = jnp.concatenate([unpack_bits(bits), frac[..., None]], axis=-1)
    return qnet_ref(x, weights)
