"""Fused DQN Q-network evaluation from PACKED fingerprints (Pallas TPU).

``fused_qnet`` already keeps the whole MolDQN MLP resident in VMEM, but it
still reads a DENSE float32 ``[N, 2049]`` input from HBM — 8 KB per row for
what is fundamentally 2048 bits + one scalar.  The learner's replay batches
arrive bit-packed (``ReplayBuffer.sample_packed``), so this kernel consumes
them directly: uint8 ``[N, 256]`` bit planes + a ``[N, 1]`` steps-left
column, 32x less input HBM traffic per row.

Because the fingerprint input is binary, the first 2049->1024 layer is a
masked row-sum of W1: row n's pre-activation is the sum of the W1 rows whose
bit is set, plus ``frac * W1[2048]`` and the bias.  The kernel realises that
sum on the MXU WITHOUT materialising a dense [N, 2048] unpack: byte plane k
(bit k of every byte, an ``[N, 256]`` 0/1 matrix) multiplies the strided
weight slice ``W1[k::8]`` (prepacked as ``w1r[8, 256, 1024]`` by ops.py),
and the 8 bit-plane matmuls accumulate —

    h1 = sum_k bits_k @ w1r[k] + frac @ w1f + b1

which is algebraically the dense ``x @ W1`` with the 2048-term reduction
re-associated into 8 x 256 (hence the 1e-5 parity tolerance vs the dense
reference instead of bit equality).  Layers 2..5 are then fused exactly as
in ``fused_qnet``.

  VMEM budget (f32): w1r 8.0 MiB + W2 2.0 MiB + W3/W4/W5 <0.3 MiB
                     + packed x block (128 x 256 u8) 32 KiB + h 0.5 MiB
                     ~= 11 MiB

Grid = (row blocks,): one packed pass over HBM for x, one output write.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _packed_qnet_kernel(bits_ref, frac_ref, w1r, w1f, b1,
                        w2, b2, w3, b3, w4, b4, w5, b5, out_ref):
    # unpack-on-the-fly: 8 bit-plane matmuls accumulate layer 1 on the MXU
    bytes32 = bits_ref[...].astype(jnp.int32)            # [rows, 256]
    frac = frac_ref[...].astype(jnp.float32)             # [rows, 1]
    h = jax.lax.dot_general(
        frac, w1f[...], (((1,), (0,)), ((), ()))) + b1[...]
    for k in range(8):                                   # np.unpackbits order:
        plane = ((bytes32 >> (7 - k)) & 1).astype(jnp.float32)  # bit k = MSB-k
        h = h + jax.lax.dot_general(
            plane, w1r[...][k], (((1,), (0,)), ((), ())))
    h = jnp.maximum(h, 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w2[...], (((1,), (0,)), ((), ()))) + b2[...], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w3[...], (((1,), (0,)), ((), ()))) + b3[...], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w4[...], (((1,), (0,)), ((), ()))) + b4[...], 0.0)
    q = jax.lax.dot_general(h, w5[...], (((1,), (0,)), ((), ()))) + b5[...]
    out_ref[...] = q[:, 0]


def packed_qnet_rows(
    bits: jnp.ndarray,         # uint8 [N, FP_BITS/8]
    frac: jnp.ndarray,         # f32 [N, 1] steps-left feature column
    w1r: jnp.ndarray,          # f32 [8, FP_BITS/8, H1] bit-plane slices of W1
    w1f: jnp.ndarray,          # f32 [1, H1] the steps-left row of W1
    b1: jnp.ndarray,           # f32 [H1]
    tail: list[tuple[jnp.ndarray, jnp.ndarray]],  # [(w, b)] layers 2..5
    *,
    row_block: int = ROW_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    N, n_bytes = bits.shape
    assert len(tail) == 4, "packed kernel is specialised to the MolDQN 5-layer MLP"
    row_block = min(row_block, N)
    assert N % row_block == 0, f"rows {N} % block {row_block}"
    grid = (N // row_block,)

    full = lambda w: pl.BlockSpec(w.shape, lambda i: (0,) * w.ndim)
    in_specs = [
        pl.BlockSpec((row_block, n_bytes), lambda i: (i, 0)),
        pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        full(w1r), full(w1f), full(b1),
    ]
    flat_w = [w1r, w1f, b1]
    for w, b in tail:
        in_specs += [full(w), full(b)]
        flat_w += [w, b]

    return pl.pallas_call(
        _packed_qnet_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(bits, frac, *flat_w)


def _packed_qnet_stacked_kernel(bits_ref, frac_ref, w1r, w1f, b1,
                                w2, b2, w3, b3, w4, b4, w5, b5, out_ref):
    # one (worker, row-block) grid cell: every ref carries a leading
    # singleton worker axis — squeeze it and run the row kernel's math
    # under THIS worker's parameter slices
    bytes32 = bits_ref[0].astype(jnp.int32)              # [rows, 256]
    frac = frac_ref[0].astype(jnp.float32)               # [rows, 1]
    h = jax.lax.dot_general(
        frac, w1f[0], (((1,), (0,)), ((), ()))) + b1[0]
    for k in range(8):                                   # np.unpackbits order:
        plane = ((bytes32 >> (7 - k)) & 1).astype(jnp.float32)  # bit k = MSB-k
        h = h + jax.lax.dot_general(
            plane, w1r[0][k], (((1,), (0,)), ((), ())))
    h = jnp.maximum(h, 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w2[0], (((1,), (0,)), ((), ()))) + b2[0], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w3[0], (((1,), (0,)), ((), ()))) + b3[0], 0.0)
    h = jnp.maximum(jax.lax.dot_general(
        h, w4[0], (((1,), (0,)), ((), ()))) + b4[0], 0.0)
    q = jax.lax.dot_general(h, w5[0], (((1,), (0,)), ((), ()))) + b5[0]
    out_ref[0] = q[:, 0]


def packed_qnet_stacked_rows(
    bits: jnp.ndarray,         # uint8 [W, C, FP_BITS/8]
    frac: jnp.ndarray,         # f32 [W, C, 1] steps-left feature column
    w1r: jnp.ndarray,          # f32 [W, 8, FP_BITS/8, H1] bit-plane W1 slices
    w1f: jnp.ndarray,          # f32 [W, 1, H1] the steps-left rows of W1
    b1: jnp.ndarray,           # f32 [W, H1]
    tail: list[tuple[jnp.ndarray, jnp.ndarray]],  # [(w, b)] layers 2..5, [W, ...]
    *,
    row_block: int = ROW_BLOCK,
    interpret: bool = False,
) -> jnp.ndarray:
    """The fleet-acting shape: grid (W, row blocks).  Each cell evaluates
    one worker's candidate-row block under that worker's own parameter
    slices (per-worker parameter selection moves into the BlockSpec index
    maps — the kernel body is the per-worker row kernel unchanged)."""
    n_workers, N, n_bytes = bits.shape
    assert len(tail) == 4, "packed kernel is specialised to the MolDQN 5-layer MLP"
    row_block = min(row_block, N)
    assert N % row_block == 0, f"rows {N} % block {row_block}"
    grid = (n_workers, N // row_block)

    per_w = lambda w: pl.BlockSpec((1,) + w.shape[1:],
                                   lambda wi, i, nd=w.ndim: (wi,) + (0,) * (nd - 1))
    in_specs = [
        pl.BlockSpec((1, row_block, n_bytes), lambda wi, i: (wi, i, 0)),
        pl.BlockSpec((1, row_block, 1), lambda wi, i: (wi, i, 0)),
        per_w(w1r), per_w(w1f), per_w(b1),
    ]
    flat_w = [w1r, w1f, b1]
    for w, b in tail:
        in_specs += [per_w(w), per_w(b)]
        flat_w += [w, b]

    return pl.pallas_call(
        _packed_qnet_stacked_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, row_block), lambda wi, i: (wi, i)),
        out_shape=jax.ShapeDtypeStruct((n_workers, N), jnp.float32),
        interpret=interpret,
    )(bits, frac, *flat_w)
