from repro.kernels.packed_qnet.ops import pack_w1, packed_qnet
from repro.kernels.packed_qnet.ref import packed_qnet_ref

__all__ = ["pack_w1", "packed_qnet", "packed_qnet_ref"]
