"""Learning-rate schedules (step -> lr, jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def exponential_decay(lr: float, decay_rate: float, decay_steps: int):
    def f(step):
        return jnp.asarray(lr, jnp.float32) * decay_rate ** (
            step.astype(jnp.float32) / decay_steps
        )
    return f


def cosine_decay(lr: float, total_steps: int, final_fraction: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr, jnp.float32) * (final_fraction + (1 - final_fraction) * cos)
    return f


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int, final_fraction: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup_steps, 1), final_fraction)
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.asarray(lr, jnp.float32) * s / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return f
