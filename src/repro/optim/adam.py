"""Adam / SGD over arbitrary pytrees.

The paper trains every model with Adam(lr=1e-4) (Appendix C, Table 3).
optax is not available in this environment, so this module provides a small
GradientTransformation-flavoured API:

    opt = adam(1e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All functions are jit-safe and shard-transparent: states mirror the param
tree leaf-for-leaf, so a pjit-sharded param tree yields an identically
sharded optimizer state (this is what makes the ZeRO-style
``shard_opt_state`` option in the launcher work with zero extra code).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree        # first moment (zeros tree for sgd)
    nu: PyTree        # second moment (zeros tree for sgd w/o momentum)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def _as_schedule(lr: float | Schedule) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, dtype=jnp.float32)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.asarray(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda x: (x * scale).astype(x.dtype), tree)


def adam(
    lr: float | Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: float | None = None,
    mu_dtype: jnp.dtype | None = None,
) -> Optimizer:
    """AdamW when weight_decay > 0, vanilla Adam otherwise."""
    schedule = _as_schedule(lr)

    def init(params: PyTree) -> OptState:
        zeros = lambda p: jnp.zeros_like(p, dtype=mu_dtype or p.dtype)
        return OptState(
            step=jnp.zeros((), dtype=jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads: PyTree, state: OptState, params: PyTree) -> tuple[PyTree, OptState]:
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = schedule(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            m_hat = m_new / bc1
            v_hat = v_new / bc2
            delta = m_hat / (jnp.sqrt(v_hat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(
    lr: float | Schedule,
    momentum: float = 0.0,
    nesterov: bool = False,
    clip_norm: float | None = None,
) -> Optimizer:
    schedule = _as_schedule(lr)

    def init(params: PyTree) -> OptState:
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return OptState(step=jnp.zeros((), dtype=jnp.int32), mu=zeros, nu=zeros)

    def update(grads: PyTree, state: OptState, params: PyTree) -> tuple[PyTree, OptState]:
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = schedule(step)

        def upd(g, m):
            m_new = momentum * m + g
            d = g + momentum * m_new if nesterov else m_new
            return -lr_t * d, m_new

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        out = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        updates = treedef.unflatten([o[0].astype(g.dtype) for o, g in zip(out, flat_g)])
        mu = treedef.unflatten([o[1] for o in out])
        return updates, OptState(step=step, mu=mu, nu=state.nu)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)
