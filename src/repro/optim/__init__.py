"""Optimizers, schedules and gradient transforms (pure JAX, optax-free)."""

from repro.optim.adam import adam, sgd, OptState, Optimizer, global_norm, clip_by_global_norm
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine, exponential_decay

__all__ = [
    "adam", "sgd", "OptState", "Optimizer", "global_norm", "clip_by_global_norm",
    "constant", "cosine_decay", "linear_warmup_cosine", "exponential_decay",
]
